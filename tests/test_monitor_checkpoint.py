"""Monitor survivability: checkpoint envelope validation, deterministic
stream-state replay, fleet-session crash/restore parity, deadline-aware
degraded mode, and the aggregator's agent-restart wiring.

(The training checkpointer's tests live in tests/test_checkpoint.py; this
file covers the *monitor* checkpoint subsystem from repro.monitor.)
"""
import dataclasses
import os
import struct
import warnings

import numpy as np
import pytest

from repro.core.engine import CorrelationEngine, StreamState
from repro.monitor import checkpoint as ckpt
from repro.monitor.aggregator import FleetAggregator
from repro.monitor.checkpoint import (
    CheckpointError, MonitorSession, load_checkpoint, save_checkpoint,
)
from repro.monitor.fleet import FleetMonitor
from repro.sim.scenario import make_trial
from repro.sim.scenarios import make_scenario
from repro.telemetry.agent import TelemetryAgent
from repro.telemetry.collectors import SimCollector


# ------------------------------------------------------------ envelope layer
def _rng_payload(rng):
    """One random JSON-able payload (the poor man's hypothesis strategy)."""
    return {
        "ints": [int(x) for x in rng.integers(-2**40, 2**40, 5)],
        "floats": [float(x) for x in rng.normal(0, 1e6, 5)],
        "nested": {"a": {"b": [float(rng.random()), None, True]}},
        "text": "".join(chr(int(c)) for c in rng.integers(32, 0x2FF, 20)),
        "empty": {},
    }


def test_checkpoint_roundtrip_property(tmp_path):
    """Round-trip over many random payloads: load(save(p)) == p exactly."""
    rng = np.random.default_rng(0)
    path = os.path.join(tmp_path, "c.ckpt")
    for _ in range(50):
        payload = _rng_payload(rng)
        n = save_checkpoint(path, payload)
        assert n == os.path.getsize(path)
        assert load_checkpoint(path) == payload


def test_checkpoint_roundtrip_hypothesis(tmp_path):
    """Same round-trip law under hypothesis, where the env provides it."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    json_vals = st.recursive(
        st.none() | st.booleans() | st.integers(-2**53, 2**53)
        | st.floats(allow_nan=False, allow_infinity=False) | st.text(),
        lambda c: st.lists(c, max_size=4)
        | st.dictionaries(st.text(max_size=8), c, max_size=4),
        max_leaves=20)

    @hyp.given(st.dictionaries(st.text(max_size=8), json_vals, max_size=6))
    @hyp.settings(max_examples=30, deadline=None)
    def roundtrip(payload):
        path = os.path.join(tmp_path, "h.ckpt")
        save_checkpoint(path, payload)
        assert load_checkpoint(path) == payload

    roundtrip()


def test_every_corrupt_byte_is_rejected(tmp_path):
    """Flipping ANY single byte of the file must raise CheckpointError —
    header fields loudly, payload bytes via the CRC."""
    path = os.path.join(tmp_path, "c.ckpt")
    save_checkpoint(path, {"k": [1, 2.5, "three"]})
    blob = open(path, "rb").read()
    for i in range(len(blob)):
        bad = bytearray(blob)
        bad[i] ^= 0x41
        open(path, "wb").write(bytes(bad))
        with pytest.raises(CheckpointError):
            load_checkpoint(path)


def test_truncation_rejected_at_every_length(tmp_path):
    path = os.path.join(tmp_path, "c.ckpt")
    save_checkpoint(path, {"k": "v" * 64})
    blob = open(path, "rb").read()
    for n in range(len(blob)):
        open(path, "wb").write(blob[:n])
        with pytest.raises(CheckpointError, match="truncated|cannot"):
            load_checkpoint(path)


def test_version_skew_rejected(tmp_path):
    path = os.path.join(tmp_path, "c.ckpt")
    save_checkpoint(path, {"k": 1})
    blob = bytearray(open(path, "rb").read())
    magic, _, ln, crc = ckpt._HEADER.unpack_from(bytes(blob))
    blob[:ckpt._HEADER.size] = ckpt._HEADER.pack(magic, ckpt.VERSION + 1,
                                                 ln, crc)
    open(path, "wb").write(bytes(blob))
    with pytest.raises(CheckpointError, match="version"):
        load_checkpoint(path)


def test_bad_magic_and_missing_file_rejected(tmp_path):
    path = os.path.join(tmp_path, "c.ckpt")
    with pytest.raises(CheckpointError, match="cannot read"):
        load_checkpoint(path)
    save_checkpoint(path, {"k": 1})
    blob = bytearray(open(path, "rb").read())
    blob[:8] = b"NOTMAGIC"
    open(path, "wb").write(bytes(blob))
    with pytest.raises(CheckpointError, match="magic"):
        load_checkpoint(path)


def test_non_object_payload_rejected(tmp_path):
    path = os.path.join(tmp_path, "c.ckpt")
    import binascii
    import json
    body = json.dumps([1, 2, 3]).encode()
    blob = ckpt._HEADER.pack(ckpt.MAGIC, ckpt.VERSION, len(body),
                             binascii.crc32(body) & 0xFFFFFFFF) + body
    open(path, "wb").write(blob)
    with pytest.raises(CheckpointError, match="not an object"):
        load_checkpoint(path)


def test_atomic_write_leaves_no_temp_files(tmp_path):
    path = os.path.join(tmp_path, "c.ckpt")
    for i in range(3):
        save_checkpoint(path, {"round": i})
    assert sorted(os.listdir(tmp_path)) == ["c.ckpt"]
    assert load_checkpoint(path) == {"round": 2}


# ------------------------------------------------- engine stream-state replay
@pytest.mark.parametrize("name", ["single", "overlap_pair", "cascade",
                                  "flap"])
def test_segmented_detect_matches_one_shot(name):
    """Cutting the stream anywhere (and round-tripping the state through
    its dict form mid-stream) yields the one-shot event stream byte for
    byte — stamps, scores and rca indices included."""
    trial = make_scenario(11, name)[0]
    ts, data, channels = trial.ts, trial.data, trial.channels
    T = ts.shape[0]
    eng = CorrelationEngine()
    # the scalar per-tick path is the oracle stateful calls replay; the
    # fast sweep agrees on every decision/stamp but its prefix-sum scores
    # round differently in the last bits
    ref = eng.detect_events(ts, data, channels, fast=False)
    fast = eng.detect_events(ts, data, channels)
    stamps = lambda evs: [(e.t_onset, e.t_detect, int(t)) for e, t in evs]
    assert stamps(fast) == stamps(ref)

    rng = np.random.default_rng(99)
    for _ in range(3):
        cuts = sorted(rng.choice(np.arange(1, T), size=5, replace=False))
        state = StreamState()
        got = []
        for hi in list(int(c) for c in cuts) + [T]:
            got += eng.detect_events(ts[:hi], data[:, :hi], channels,
                                     state=state)
            # checkpoint/restore mid-stream must be a no-op for replay
            state = StreamState.from_dict(state.to_dict())
        got += state.flush(T)
        sig = lambda evs: [(e.t_onset, e.t_detect, e.score, int(t))
                           for e, t in evs]
        assert sig(got) == sig(ref)


def test_stream_state_skips_already_seen_ticks():
    trial = make_scenario(5, "single")[0]
    eng = CorrelationEngine()
    state = StreamState()
    first = eng.detect_events(trial.ts, trial.data, trial.channels,
                              state=state)
    again = eng.detect_events(trial.ts, trial.data, trial.channels,
                              state=state)
    assert again == []                 # every tick already seen
    assert len(first) >= 1


def test_stream_state_roundtrip_any_hypothesis_count():
    """to_dict/from_dict is exact for 0..K concurrent hypotheses, in any
    maturation mix, including through the JSON encoding the checkpoint
    envelope applies."""
    import json

    from repro.core.engine import EngineConfig, Hypothesis
    from repro.core.taxonomy import SpikeEvent

    cfg = EngineConfig()
    for k in range(cfg.max_hypotheses + 1):
        st = StreamState(
            hypotheses=[Hypothesis(
                event=SpikeEvent(t_onset=10.25 + i, t_detect=12.5 + i,
                                 score=3.5 + 0.125 * i,
                                 metric="coll_allreduce_ms"),
                rca_at=1500 + 100 * i, matured=bool(i % 2),
                mu=5.0 + i, sd=0.25 * (i + 1)) for i in range(k)],
            t_seen=99.5 if k else -np.inf)
        assert StreamState.from_dict(st.to_dict()) == st
        wire = json.loads(json.dumps(st.to_dict()))
        assert StreamState.from_dict(wire) == st


def test_stream_state_rejects_single_pending_shape():
    """The retired single-pending state shape (pre-hypothesis-set) must
    raise loudly — a silent partial restore would resurrect an engine
    with no concurrent-incident memory."""
    legacy = {"pending": None, "cooldown_until": 17.5, "t_seen": 40.0}
    with pytest.raises(KeyError):
        StreamState.from_dict(legacy)


def test_segmented_replay_crash_between_concurrent_onsets():
    """A checkpoint round trip landing between two concurrent detections
    — the first hypothesis live when the stream cuts, the second opening
    only after the restore — still replays the one-shot stream byte for
    byte."""
    eng = CorrelationEngine()
    for seed in (11, 12, 13, 14):
        trial = make_scenario(seed, "overlap_pair")[0]
        ts, data, channels = trial.ts, trial.data, trial.channels
        ref = eng.detect_events(ts, data, channels, fast=False)
        if len(ref) < 2:
            continue
        t1, t2 = ref[0][0].t_detect, ref[1][0].t_detect
        if not (0.0 < t2 - t1 < eng.cfg.cooldown_s):
            continue          # want the second INSIDE the first's cooldown
        hi = int(np.searchsorted(ts, (t1 + t2) / 2.0))
        state = StreamState()
        got = list(eng.detect_events(ts[:hi], data[:, :hi], channels,
                                     state=state))
        assert state.hypotheses, "cut must land on a live hypothesis"
        state = StreamState.from_dict(state.to_dict())   # crash + restore
        got += eng.detect_events(ts, data, channels, state=state)
        got += state.flush(ts.shape[0])
        sig = lambda evs: [(e.t_onset, e.t_detect, e.score, int(t))
                           for e, t in evs]
        assert sig(got) == sig(ref)
        break
    else:
        pytest.fail("no overlap_pair seed produced concurrent detections")


# ------------------------------------------------------- fleet session replay
def _fleet_windows(n_hosts=4, bad_host=2, cls="nic", seed=800):
    trials = [make_trial(seed + h, cls,
                         intensity=(2.0 if h == bad_host else 0.0),
                         t_on=40.0, confuser_prob=0.0)
              for h in range(n_hosts)]
    t_hi = int(46.0 * trials[0].rate_hz)
    slab = np.ascontiguousarray(
        np.stack([t.data[:, :t_hi] for t in trials]), np.float32)
    ts = trials[0].ts[:t_hi]
    ticks = [min(int(r * trials[0].rate_hz), ts.shape[0])
             for r in range(36, 47)]
    return ts, slab, trials[0].channels, ticks


def _drive(sess, ts, slab, ticks, skip=(), replay_from=None, **kw):
    out = []
    for k, hi in enumerate(ticks):
        if k in skip:
            continue
        out += sess.tick(ts[:hi], slab[:, :, :hi],
                         replay=(k == replay_from), **kw)[1]
    return out


@pytest.mark.parametrize("crash_round", [2, 4, 6])
def test_fleet_crash_restore_replay_parity(tmp_path, crash_round):
    """Crash after ``crash_round`` rounds, restore a FRESH monitor+session
    from the checkpoint, replay the remaining windows: verdict stream
    byte-identical to an uninterrupted session, zero duplicates."""
    ts, slab, channels, ticks = _fleet_windows()
    path = os.path.join(tmp_path, "m.ckpt")

    base = _drive(MonitorSession(FleetMonitor(use_kernels=False), channels),
                  ts, slab, ticks)
    assert base, "fixture must produce at least one verdict"

    sess = MonitorSession(FleetMonitor(use_kernels=False), channels)
    got = _drive(sess, ts, slab, ticks[:crash_round])
    sess.save(path)
    # process dies; cold objects warm-restore
    sess2 = MonitorSession(FleetMonitor(use_kernels=False), channels)
    assert sess2.restore(path) is True
    assert sess2.stats.restarts == 1
    got += _drive(sess2, ts, slab, ticks,
                  skip=set(range(crash_round)), replay_from=crash_round)

    sigs = [v.sig() for v in got]
    assert sigs == [v.sig() for v in base]
    assert len(sigs) == len(set(sigs))      # no duplicate verdicts


def test_replay_reemission_suppressed_by_restored_cooldown(tmp_path):
    """A verdict delivered before the crash and re-derived by the replay
    is suppressed by the restored cooldown map and counted."""
    ts, slab, channels, ticks = _fleet_windows()
    path = os.path.join(tmp_path, "m.ckpt")
    sess = MonitorSession(FleetMonitor(use_kernels=False), channels)
    verdicts = []
    crash_at = None
    for k, hi in enumerate(ticks):
        verdicts += sess.tick(ts[:hi], slab[:, :, :hi])[1]
        sess.save(path)
        if verdicts and crash_at is None:
            crash_at = k
            break
    assert crash_at is not None
    sess2 = MonitorSession(FleetMonitor(use_kernels=False), channels)
    assert sess2.restore(path)
    extra = _drive(sess2, ts, slab, ticks, skip=set(range(crash_at + 1)),
                   replay_from=crash_at + 1)
    assert sess2.stats.duplicates_suppressed >= 1
    all_sigs = [v.sig() for v in verdicts + extra]
    assert len(all_sigs) == len(set(all_sigs))
    assert sess2.stats.replay_ticks > 0


def test_corrupt_checkpoint_falls_back_to_cold_start(tmp_path):
    ts, slab, channels, ticks = _fleet_windows()
    path = os.path.join(tmp_path, "m.ckpt")
    sess = MonitorSession(FleetMonitor(use_kernels=False), channels)
    _drive(sess, ts, slab, ticks[:3])
    sess.save(path)
    blob = bytearray(open(path, "rb").read())
    blob[-10] ^= 0xFF
    open(path, "wb").write(bytes(blob))

    sess2 = MonitorSession(FleetMonitor(use_kernels=False), channels)
    with pytest.warns(RuntimeWarning, match="cold start"):
        ok = sess2.restore(path)
    assert ok is False
    assert sess2.stats.checkpoints_rejected == 1
    assert sess2.stats.restarts == 0
    # cold state untouched: no cooldowns, no baselines, -inf horizon
    assert sess2._cooldown_until == {} and sess2._base_n == {}
    assert sess2._t_seen == -np.inf


def test_malformed_payload_never_half_restores(tmp_path):
    """A checkpoint whose envelope is valid but whose payload is missing a
    later field must not mutate ANY session state (parse-all-then-assign)."""
    ts, slab, channels, ticks = _fleet_windows()
    path = os.path.join(tmp_path, "m.ckpt")
    sess = MonitorSession(FleetMonitor(use_kernels=False), channels)
    _drive(sess, ts, slab, ticks[:4])
    payload = sess.state_dict()
    del payload["baseline"]                 # envelope fine, payload not
    save_checkpoint(path, payload)

    sess2 = MonitorSession(FleetMonitor(use_kernels=False), channels)
    with pytest.warns(RuntimeWarning):
        assert sess2.restore(path) is False
    assert sess2._cooldown_until == {}
    assert sess2._t_seen == -np.inf
    assert sess2.monitor._strikes == {}


def test_baseline_moments_replay_bit_identical(tmp_path):
    """Welford chunk-merge moments converge bit-identically between an
    uninterrupted session and a crash/restore replay over the same chunk
    boundaries."""
    ts, slab, channels, ticks = _fleet_windows()
    path = os.path.join(tmp_path, "m.ckpt")
    a = MonitorSession(FleetMonitor(use_kernels=False), channels)
    _drive(a, ts, slab, ticks)

    b = MonitorSession(FleetMonitor(use_kernels=False), channels)
    _drive(b, ts, slab, ticks[:5])
    b.save(path)
    b2 = MonitorSession(FleetMonitor(use_kernels=False), channels)
    assert b2.restore(path)
    _drive(b2, ts, slab, ticks, skip=set(range(5)), replay_from=5)

    for h in range(slab.shape[0]):
        na, ma, va = a.baseline_moments(h)
        nb, mb, vb = b2.baseline_moments(h)
        np.testing.assert_array_equal(na, nb)
        np.testing.assert_array_equal(ma, mb)
        np.testing.assert_array_equal(va, vb)


# --------------------------------------------------------- degraded mode
def test_degraded_mode_sheds_defers_and_rearms():
    ts, slab, channels, ticks = _fleet_windows()
    mon = FleetMonitor(use_kernels=False, budget_s=0.05, shed_after=2,
                       rearm_after=3)
    sess = MonitorSession(mon, channels)
    degraded_seen = deferred_seen = False
    for k, hi in enumerate(ticks):
        cost = 1.0 if k < 6 else 0.0
        fd, _ = sess.tick(ts[:hi], slab[:, :, :hi], extra_cost_s=cost)
        degraded_seen |= fd.degraded
        deferred_seen |= bool(fd.deferred_hosts)
    assert degraded_seen, "budget hysteresis never degraded"
    assert deferred_seen, "no fresh host had its RCA deferred"
    assert mon.shed_rounds >= 1
    assert mon.deferred_rca >= 1
    assert not mon.degraded, "budget never re-armed after load lifted"


def test_degraded_mode_prioritizes_strike_carrying_hosts():
    """While degraded, a host with prior strikes keeps full RCA; a fresh
    flagged host is detect-only (deferred, mitigation NONE)."""
    ts, slab, channels, ticks = _fleet_windows(bad_host=2)
    mon = FleetMonitor(use_kernels=False, budget_s=0.05, shed_after=1,
                       rearm_after=99)
    # round 1 on-budget: bad host earns a strike with a full diagnosis
    fd0 = mon.diagnose_fleet(ts[:ticks[-1]], slab[:, :, :ticks[-1]],
                             channels)
    assert 2 in fd0.flagged_hosts and 2 in fd0.diagnoses
    assert mon._strikes.get(2, 0) >= 1
    # hammer the budget until degraded, keeping the incident in-window so
    # the host's strike history survives (clean rounds would clear it)
    while not mon.degraded:
        mon.diagnose_fleet(ts[:ticks[-1]], slab[:, :, :ticks[-1]],
                           channels, extra_cost_s=1.0)
    fd1 = mon.diagnose_fleet(ts[:ticks[-1]], slab[:, :, :ticks[-1]],
                             channels, extra_cost_s=1.0)
    assert fd1.degraded
    # the striked host still gets a diagnosis while degraded
    assert 2 in fd1.diagnoses
    assert 2 not in fd1.deferred_hosts


def test_degraded_field_survives_checkpoint(tmp_path):
    ts, slab, channels, ticks = _fleet_windows()
    path = os.path.join(tmp_path, "m.ckpt")
    mon = FleetMonitor(use_kernels=False, budget_s=0.05, shed_after=1,
                       rearm_after=3)
    sess = MonitorSession(mon, channels)
    sess.tick(ts[:ticks[0]], slab[:, :, :ticks[0]], extra_cost_s=1.0)
    assert mon.degraded
    sess.save(path)
    mon2 = FleetMonitor(use_kernels=False, budget_s=0.05, shed_after=1,
                        rearm_after=3)
    sess2 = MonitorSession(mon2, channels)
    assert sess2.restore(path)
    assert mon2.degraded
    assert mon2.shed_rounds == mon.shed_rounds


def test_non_degraded_rounds_identical_with_budget_disabled():
    """budget_s=None (the default) must leave diagnose_fleet byte-identical
    to a budgeted monitor that never trips: degraded stays a pure add-on."""
    ts, slab, channels, ticks = _fleet_windows()
    hi = ticks[-1]
    a = FleetMonitor(use_kernels=False)
    b = FleetMonitor(use_kernels=False, budget_s=1e9)
    fa = a.diagnose_fleet(ts[:hi], slab[:, :, :hi], channels)
    fb = b.diagnose_fleet(ts[:hi], slab[:, :, :hi], channels)
    assert fa.flagged_hosts == fb.flagged_hosts
    assert not fa.degraded and not fb.degraded
    assert fa.deferred_hosts == [] and fb.deferred_hosts == []
    for h in fa.diagnoses:
        assert fa.diagnoses[h].event.t_onset == fb.diagnoses[h].event.t_onset
        assert fa.diagnoses[h].top_cause == fb.diagnoses[h].top_cause


# -------------------------------------------------- reset_host + aggregator
def test_reset_host_clears_strike_and_quarantine_state():
    ts, slab, channels, ticks = _fleet_windows(bad_host=1)
    mon = FleetMonitor(use_kernels=False, persistent_threshold=2)
    hi = ticks[-1]
    mon.diagnose_fleet(ts[:hi], slab[:, :, :hi], channels)
    mon.diagnose_fleet(ts[:hi], slab[:, :, :hi], channels)
    assert mon._strikes.get(1, 0) >= 2
    mon.reset_host(1)
    assert 1 not in mon._strikes
    fd = mon.diagnose_fleet(ts[:hi], slab[:, :, :hi], channels)
    # history gone: the host re-earns its first strike from scratch
    assert mon._strikes.get(1, 0) == 1
    assert 1 in fd.flagged_hosts


def test_agent_restart_wires_reset_host_through_aggregator():
    trials = [make_trial(860 + h, "nic",
                         intensity=(2.0 if h == 1 else 0.0),
                         t_on=40.0, confuser_prob=0.0) for h in range(3)]
    agents = [TelemetryAgent([SimCollector(t.channels, t.ts, t.data)],
                             rate_hz=100.0, history_s=60.0) for t in trials]
    agg = FleetAggregator(agents, window_s=40.0)
    agg.run_virtual(0.0, 46.0)
    mon = FleetMonitor(use_kernels=False, persistent_threshold=2)
    agg.diagnose(mon)
    agg.diagnose(mon)
    assert mon._strikes.get(1, 0) >= 2

    agg.restart_agent(1)
    assert agents[1].stats.restarts == 1
    assert agg.stats.agent_restarts == 1
    # the reset is delivered at the next diagnose, exactly once
    agg.run_virtual(46.0, 46.5)
    agg.diagnose(mon)
    assert agg.stats.host_resets == 1
    assert mon._strikes.get(1, 0) <= 1


def test_agent_restart_counters_in_snapshots():
    t = make_trial(870, "nic", intensity=0.0, t_on=40.0, confuser_prob=0.0)
    agent = TelemetryAgent([SimCollector(t.channels, t.ts, t.data)],
                           rate_hz=100.0, history_s=10.0)
    agent.run_virtual(0.0, 5.0)
    assert agent.stats.restarts == 0
    agent.restart()
    assert agent.stats.restarts == 1
    # ring/history survive a restart — only failure state is cleared
    ts, _ = agent.window(2.0)
    assert ts.shape[0] > 0


def test_ring_read_since_returns_only_new_samples():
    t = make_trial(880, "nic", intensity=0.0, t_on=40.0, confuser_prob=0.0)
    agent = TelemetryAgent([SimCollector(t.channels, t.ts, t.data)],
                           rate_hz=100.0, history_s=30.0)
    agent.run_virtual(0.0, 10.0)
    ring = agent.ring
    ts_all, _, n_all = ring.read_since(-np.inf)
    assert n_all == ts_all.shape[0] > 0
    cut = float(ts_all[n_all // 2])
    ts_new, data_new, n_new = ring.read_since(cut)
    assert n_new == ts_new.shape[0]
    assert np.all(ts_new > cut)
    assert data_new.shape[1] == n_new
    _, _, none_new = ring.read_since(float(ts_all[-1]))
    assert none_new == 0


def test_session_stats_roundtrip_in_checkpoint(tmp_path):
    ts, slab, channels, ticks = _fleet_windows()
    path = os.path.join(tmp_path, "m.ckpt")
    sess = MonitorSession(FleetMonitor(use_kernels=False), channels)
    _drive(sess, ts, slab, ticks[:4])
    sess.save(path)
    payload = load_checkpoint(path)
    assert payload["stats"]["rounds"] == 4
    assert payload["stats"]["checkpoints_written"] == 0  # pre-save snapshot
    assert dataclasses.asdict(sess.stats)["checkpoints_written"] == 1


def test_restore_cold_invalidates_incremental_moments(tmp_path):
    """Incremental moments are deliberately NOT serialized: the
    checkpoint stays flat, a warm restore lands cold, and the first
    post-restore round is a forced from-scratch re-anchor — while the
    replayed verdict stream stays byte-identical (replay parity)."""
    ts, slab, channels, ticks = _fleet_windows()
    path = os.path.join(tmp_path, "m.ckpt")

    base = _drive(MonitorSession(FleetMonitor(use_kernels=False), channels),
                  ts, slab, ticks)

    sess = MonitorSession(FleetMonitor(use_kernels=False), channels)
    got = _drive(sess, ts, slab, ticks[:4])
    st = sess.monitor.incremental_stats()
    assert st is not None and st["rounds"] >= 1    # state was warm
    # flat checkpoint: no moment arrays ride along
    payload = sess.monitor.state_dict()
    assert not any("moment" in k or "rolling" in k for k in payload)
    sess.save(path)

    sess2 = MonitorSession(FleetMonitor(use_kernels=False), channels)
    assert sess2.restore(path) is True
    inc2 = sess2.monitor._inc
    assert inc2 is not None and (inc2._bid == -1).all()  # restored cold
    got += _drive(sess2, ts, slab, ticks, skip=set(range(4)),
                  replay_from=4)
    st2 = sess2.monitor.incremental_stats()
    assert st2["rounds"] >= 1
    assert st2["parity"] == 1.0
    assert [v.sig() for v in got] == [v.sig() for v in base]


def test_load_state_dict_invalidates_warm_moments():
    """Restoring INTO an already-warm monitor must drop its carried
    moment cache — restored verdict state and stale moment state may
    not mix."""
    ts, slab, channels, ticks = _fleet_windows()
    mon = FleetMonitor(use_kernels=False)
    for hi in ticks[:3]:
        mon.diagnose_fleet(ts[:hi], slab[:, :, :hi], channels)
    assert (mon._inc._bid >= 0).any()              # warm cache
    mon.load_state_dict(mon.state_dict())
    assert (mon._inc._bid == -1).all()             # wiped on restore
