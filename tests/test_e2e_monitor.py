"""End-to-end pipeline: SimCollector -> TelemetryAgent (virtual clock) ->
ring window -> CorrelationEngine -> diagnosis.  This is the deployment
data path (the eval harness feeds the engine directly; this test goes
through the agent like production does)."""
import numpy as np

from repro.core.engine import CorrelationEngine
from repro.core.taxonomy import CauseClass
from repro.sim.scenario import make_trial
from repro.telemetry.agent import TelemetryAgent
from repro.telemetry.collectors import SimCollector


def test_agent_to_engine_pipeline():
    trial = make_trial(21, "io", intensity=2.0, t_on=40.0,
                       confuser_prob=0.0)
    sim = SimCollector(trial.channels, trial.ts, trial.data)
    agent = TelemetryAgent([sim], rate_hz=100.0, history_s=120.0)
    agent.run_virtual(0.0, 60.0)
    assert agent.stats.samples == 6000

    ts, data = agent.window(60.0)
    # agent channels are sorted; engine takes names alongside
    diags = CorrelationEngine().process(ts, data, agent.channels)
    assert diags, "no diagnosis through the agent path"
    assert diags[0].top_cause == CauseClass.IO
    # detection timing consistent with the direct path
    assert 40.0 < diags[0].event.t_detect < 50.0


def test_agent_window_matches_source():
    trial = make_trial(22, "nic", intensity=1.5, confuser_prob=0.0)
    sim = SimCollector(trial.channels, trial.ts, trial.data)
    agent = TelemetryAgent([sim], rate_hz=100.0, history_s=30.0)
    agent.run_virtual(0.0, 20.0)
    ts, data = agent.window(5.0)
    assert data.shape == (len(agent.channels), 500)
    i_agent = agent.channels.index("nic_rx_bytes")
    i_src = trial.channels.index("nic_rx_bytes")
    # agent's view of the channel equals the source at the sampled instants
    # (same right-side ZOH lookup as SimCollector, epsilon for float grid)
    idx = np.searchsorted(trial.ts, ts + 1e-9, side="right") - 1
    src = trial.data[i_src, np.clip(idx, 0, trial.ts.size - 1)]
    np.testing.assert_allclose(data[i_agent], src.astype(np.float32),
                               rtol=1e-5)
