import numpy as np
import pytest

from repro.core.baselines import make_baseline
from repro.core.taxonomy import CauseClass
from repro.sim.scenario import make_trial


@pytest.mark.parametrize("name", ["ours", "b1", "b2", "b3"])
def test_all_baselines_return_verdicts(name):
    trial = make_trial(42, "gpu", intensity=2.2, confuser_prob=0.0)
    dg = make_baseline(name)
    res = dg.diagnose_trial(trial.ts, trial.data, trial.channels)
    assert isinstance(res.pred, CauseClass)
    assert res.pred != CauseClass.UNKNOWN


def test_b1_sees_gpu_directly():
    trial = make_trial(43, "gpu", intensity=2.0, confuser_prob=0.0)
    res = make_baseline("b1").diagnose_trial(trial.ts, trial.data,
                                             trial.channels)
    assert res.pred == CauseClass.GPU
    assert res.t_rca is not None and res.t_rca > trial.t_on + 30


def test_b2_is_offline_slow():
    trial = make_trial(44, "io", intensity=2.0, confuser_prob=0.0)
    res = make_baseline("b2").diagnose_trial(trial.ts, trial.data,
                                             trial.channels)
    assert res.t_rca - trial.t_on > 20.0


def test_ours_faster_than_deep_profiling():
    trial = make_trial(45, "cpu", intensity=2.0, confuser_prob=0.0)
    ours = make_baseline("ours").diagnose_trial(trial.ts, trial.data.copy(),
                                                trial.channels)
    b3 = make_baseline("b3").diagnose_trial(trial.ts, trial.data.copy(),
                                            trial.channels)
    assert ours.t_rca is not None and b3.t_rca is not None
    assert ours.t_rca < b3.t_rca
