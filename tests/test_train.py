"""Training stack: optimizer math, microbatch equivalence, learnability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import PipelineConfig, SyntheticLMPipeline
from repro.models.registry import build_model
from repro.train.optimizer import (
    OptConfig, adamw_init, adamw_update, adafactor_init, adafactor_update,
    clip_by_global_norm, global_norm,
)
from repro.train.step import build_train_step, init_train_state


def test_adamw_moves_against_gradient():
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.ones((4,))}
    st = adamw_init(params)
    p2, st2 = adamw_update(cfg, params, grads, st, jnp.asarray(0))
    assert np.all(np.asarray(p2["w"]) < 1.0)


def test_adafactor_factored_states():
    cfg = OptConfig(kind="adafactor", min_dim_factored=4)
    params = {"big": jnp.ones((8, 8)), "small": jnp.ones((3,))}
    st = adafactor_init(params, cfg)
    assert "vr" in st["f"]["big"] and "v" in st["f"]["small"]
    grads = jax.tree.map(jnp.ones_like, params)
    p2, st2 = adafactor_update(cfg, params, grads, st, jnp.asarray(0))
    assert np.all(np.asarray(p2["big"]) < 1.0)


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_microbatch_equivalence():
    """grad accumulation over 4 microbatches == single big batch (same
    update, modulo fp noise)."""
    cfg = get_config("yi-9b", smoke=True)
    model = build_model(cfg)
    opt = OptConfig(lr=1e-3, warmup_steps=1)
    rng = np.random.default_rng(0)
    B, S = 8, 16
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    s0 = init_train_state(model, jax.random.key(0), opt)
    step1 = jax.jit(build_train_step(model, opt, microbatch=0))
    step4 = jax.jit(build_train_step(model, opt, microbatch=4))
    s1, m1 = step1(s0, batch)
    s4, m4 = step4(init_train_state(model, jax.random.key(0), opt), batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-2)
    w1 = np.asarray(jax.tree.leaves(s1.params)[0], np.float32)
    w4 = np.asarray(jax.tree.leaves(s4.params)[0], np.float32)
    np.testing.assert_allclose(w1, w4, atol=2e-2, rtol=2e-2)


def test_loss_decreases_on_learnable_data():
    cfg = get_config("yi-9b", smoke=True).replace(n_layers=2)
    model = build_model(cfg)
    opt = OptConfig(lr=3e-3, warmup_steps=5)
    pipe = SyntheticLMPipeline(PipelineConfig(batch=8, seq_len=32,
                                              vocab=cfg.vocab, seed=0,
                                              motif_prob=1.0, motif_len=8))
    state = init_train_state(model, jax.random.key(0), opt)
    step = jax.jit(build_train_step(model, opt))
    losses = []
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses
