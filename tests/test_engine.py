import numpy as np
import pytest

from repro.core.engine import CorrelationEngine, EngineConfig
from repro.core.taxonomy import CauseClass
from repro.sim.scenario import make_trial


@pytest.mark.parametrize("cls,expected", [
    ("io", CauseClass.IO), ("cpu", CauseClass.CPU),
    ("nic", CauseClass.NIC), ("gpu", CauseClass.GPU),
])
def test_diagnoses_strong_trials(cls, expected):
    # strong, confuser-free trials must be diagnosed correctly
    trial = make_trial(123, cls, intensity=2.0, confuser_prob=0.0)
    eng = CorrelationEngine()
    diags = eng.process(trial.ts, trial.data, trial.channels)
    assert diags, f"no spike detected for {cls}"
    assert diags[0].top_cause == expected


def test_timing_fields():
    trial = make_trial(7, "cpu", intensity=2.0, confuser_prob=0.0)
    eng = CorrelationEngine()
    d = eng.process(trial.ts, trial.data, trial.channels)[0]
    assert d.event.t_detect >= d.event.t_onset
    assert d.t_rca >= d.event.t_detect
    # detection happens within ~2 windows of true onset
    assert abs(d.event.t_onset - trial.t_on) < 6.0
    assert d.time_to_rca < 15.0
    assert d.analysis_seconds < 1.0


def test_no_event_on_quiet_trial():
    # zero-intensity disturbance -> no spike -> no diagnosis
    trial = make_trial(11, "io", intensity=0.0, confuser_prob=0.0)
    # intensity clip floor is >0; force flat multiplier by zeroing effects
    eng = CorrelationEngine(EngineConfig(threshold=6.0, persistence=0.9))
    diags = eng.process(trial.ts, trial.data, trial.channels)
    assert len(diags) <= 1  # at most a marginal event at extreme settings


def test_evidence_channel_restriction():
    trial = make_trial(5, "nic", intensity=2.0, confuser_prob=0.0)
    # restrict evidence away from NET channels: NIC cannot be diagnosed
    allowed = [c for c in trial.channels
               if not c.startswith(("net_", "nic_"))]
    eng = CorrelationEngine(evidence_channels=allowed)
    diags = eng.process(trial.ts, trial.data, trial.channels)
    if diags:
        assert diags[0].top_cause != CauseClass.NIC


def test_ranked_causes_sorted():
    trial = make_trial(9, "io", intensity=2.0)
    d = CorrelationEngine().process(trial.ts, trial.data, trial.channels)[0]
    confs = [rc.confidence for rc in d.ranked]
    assert confs == sorted(confs, reverse=True)
    assert len({rc.cause for rc in d.ranked}) == len(d.ranked)
