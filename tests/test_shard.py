"""Sharded fleet monitor: byte-exact parity with the single-slab path
(flagged order, scores, causes, quarantine, degraded/deferred fields),
shard edge cases (ragged shards, dead shards, late joiners), provider
re-visit semantics, traffic bounds, and shard-aware checkpointing."""
import numpy as np
import pytest

from benchmarks.fleetbench import _make_fleet
from repro.monitor import (
    FleetAggregator, FleetMonitor, Mitigation, ShardPlan,
    ShardedFleetMonitor, verdict_fingerprint,
)
from repro.monitor.checkpoint import MonitorSession

LAT = "coll_allreduce_ms"   # EngineConfig.latency_metric


def _plan(hosts=48):
    """Deliberately ragged: 20 + 20 + (hosts-40), two shards per rack."""
    return ShardPlan.from_bounds([(0, 20), (20, 40), (40, hosts)],
                                 rack_shards=2)


def _pair(hosts=48, **kw):
    """(single-slab monitor, sharded monitor) with identical knobs."""
    return (FleetMonitor(use_kernels=False, **kw),
            ShardedFleetMonitor(_plan(hosts), use_kernels=False, **kw))


def _state_no_plan(mon):
    d = dict(mon.state_dict())
    d.pop("shard_plan", None)
    return d


# --------------------------------------------------------------- ShardPlan

def test_plan_validates_contiguous_tiling_and_rack_partition():
    with pytest.raises(ValueError):
        ShardPlan(bounds=((0, 4), (5, 8)), racks=((0, 1),))    # gap
    with pytest.raises(ValueError):
        ShardPlan(bounds=((0, 4), (3, 8)), racks=((0, 1),))    # overlap
    with pytest.raises(ValueError):
        ShardPlan(bounds=((1, 4),), racks=((0,),))             # not from 0
    with pytest.raises(ValueError):
        ShardPlan(bounds=((0, 4), (4, 4)), racks=((0, 1),))    # empty shard
    with pytest.raises(ValueError):
        ShardPlan(bounds=((0, 4), (4, 8)), racks=((0,),))      # shard 1 lost
    with pytest.raises(ValueError):
        ShardPlan(bounds=((0, 4),), racks=((0, 0),))           # duplicate


def test_plan_helpers_and_dict_round_trip():
    p = ShardPlan.for_fleet(10, shard_hosts=4, rack_shards=2)
    assert p.bounds == ((0, 4), (4, 8), (8, 10))   # ragged tail shard
    assert p.racks == ((0, 1), (2,))
    assert (p.hosts, p.n_shards, p.n_racks) == (10, 3, 2)
    assert [p.shard_of(h) for h in (0, 3, 4, 9)] == [0, 0, 1, 2]
    with pytest.raises(ValueError):
        p.shard_of(10)
    assert ShardPlan.from_dict(p.to_dict()) == p


def test_aggregator_shard_plan_covers_fleet():
    agg = FleetAggregator.__new__(FleetAggregator)
    agg.agents = list(range(7))
    p = agg.shard_plan(shard_hosts=3, rack_shards=2)
    assert p.hosts == 7 and p.bounds == ((0, 3), (3, 6), (6, 7))


# ----------------------------------------------------------------- parity

def test_clean_round_parity_ragged_shards():
    """One straggler on a ragged 3-shard plan: identical fingerprint,
    identical monitor state after the round, bounded traffic."""
    ts, data, channels = _make_fleet(48, bad_host=5, seed=0)
    mono, shard = _pair()
    a = mono.diagnose_fleet(ts, data, channels)
    b = shard.diagnose_fleet(ts, data, channels)
    assert a.flagged_hosts == [5]
    assert verdict_fingerprint(a) == verdict_fingerprint(b)
    assert _state_no_plan(shard) == mono.state_dict()
    tr = shard.last_traffic
    assert tr is not None and tr.raw_bytes > 0
    assert tr.total_bytes < tr.raw_bytes


def test_multi_round_strike_escalation_parity():
    """Strike history lives on absolute host ids: escalation to
    EXCLUDE_AND_RESCALE happens on the same round on both paths."""
    ts, data, channels = _make_fleet(48, bad_host=41, seed=7)  # last shard
    mono, shard = _pair(persistent_threshold=2)
    for rnd in range(2):
        a = mono.diagnose_fleet(ts, data, channels)
        b = shard.diagnose_fleet(ts, data, channels)
        assert verdict_fingerprint(a) == verdict_fingerprint(b), rnd
    assert b.mitigation == Mitigation.EXCLUDE_AND_RESCALE
    assert _state_no_plan(shard) == mono.state_dict()


def test_incident_storm_topk_parity_and_deferral():
    """Storm (every 6th host injected) with a fleet-level RCA cap: the
    rack tree must ship exactly the evidence the fleet selection needs,
    and the overflow lands in deferred_hosts on both paths."""
    ts, data, channels = _make_fleet(48, bad_host=5, seed=11, bad_every=6)
    mono, shard = _pair(rca_top_k=3)
    a = mono.diagnose_fleet(ts, data, channels)
    b = shard.diagnose_fleet(ts, data, channels)
    assert len(a.flagged_hosts) > 3
    assert a.deferred_hosts and len(a.diagnoses) <= 3
    assert verdict_fingerprint(a) == verdict_fingerprint(b)
    # the cap also bounds evidence traffic: at most top-K blocks per rack
    assert shard.last_traffic.n_evidence <= 3 * shard.plan.n_racks


def test_quarantine_entry_parity_under_corruption():
    """A host whose latency channel is mostly invalid enters quarantine
    after ``enter_rounds`` rounds — same round, same fingerprint, on both
    paths (the corrupt cell routes every shard through the f64 oracle)."""
    ts, data, channels = _make_fleet(48, bad_host=5, seed=3)
    li = channels.index(LAT)
    valid = np.ones(data.shape, bool)
    valid[44, li, -1200:] = False          # last shard, ~39% of the tail
    mono, shard = _pair()
    for rnd in range(2):
        a = mono.diagnose_fleet(ts, data, channels, valid=valid)
        b = shard.diagnose_fleet(ts, data, channels, valid=valid)
        assert verdict_fingerprint(a) == verdict_fingerprint(b), rnd
    assert a.quarantined == [44]
    assert a.mitigations[44] == Mitigation.RESTART_TELEMETRY
    assert 44 not in a.flagged_hosts


def test_whole_dead_shard_quarantined_parity():
    """Every host of shard 2 reports all-invalid telemetry: the whole
    shard quarantines, nothing in it is ever named straggler, and the
    live shards' verdicts match the single-slab round bit for bit."""
    ts, data, channels = _make_fleet(48, bad_host=5, seed=13)
    valid = np.ones(data.shape, bool)
    valid[40:48] = False
    mono, shard = _pair()
    for _ in range(2):
        a = mono.diagnose_fleet(ts, data, channels, valid=valid)
        b = shard.diagnose_fleet(ts, data, channels, valid=valid)
        assert verdict_fingerprint(a) == verdict_fingerprint(b)
    assert a.quarantined == list(range(40, 48))
    assert a.straggler_host == 5


def test_degraded_mode_parity():
    """Deadline-degraded rounds (budget always blown): shed/deferral and
    the strike-priority selection agree across paths every round.  The
    storm widens AFTER degradation engages, so the new stragglers have no
    strike history and must be deferred — while the original one, already
    carrying a strike, still gets full RCA."""
    ts, calm, channels = _make_fleet(48, bad_host=5, seed=17)
    _, storm, _ = _make_fleet(48, bad_host=5, seed=17, bad_every=6)
    mono, shard = _pair(budget_s=1e-6, shed_after=1)
    rounds = []
    for rnd, data in enumerate((calm, storm, storm)):
        a = mono.diagnose_fleet(ts, data, channels)
        b = shard.diagnose_fleet(ts, data, channels)
        assert verdict_fingerprint(a) == verdict_fingerprint(b), rnd
        rounds.append(a)
    first_storm = rounds[1]     # degraded, and the new stragglers are fresh
    assert first_storm.degraded and first_storm.deferred_hosts
    assert 5 in first_storm.diagnoses
    assert 5 not in first_storm.deferred_hosts
    # by the next round the deferred hosts carry strikes and get full RCA
    assert rounds[2].deferred_hosts == []
    assert set(first_storm.deferred_hosts) <= set(rounds[2].diagnoses)
    assert shard.shed_rounds == mono.shed_rounds
    assert shard.deferred_rca == mono.deferred_rca


def test_short_window_quiet_parity():
    ts, data, channels = _make_fleet(48, bad_host=5, seed=19)
    mono, shard = _pair()
    a = mono.diagnose_fleet(ts[:40], data[:, :, :40], channels)
    b = shard.diagnose_fleet(ts[:40], data[:, :, :40], channels)
    assert a.flagged_hosts == [] and "short_baseline_skip" in a.stage_seconds
    assert verdict_fingerprint(a) == verdict_fingerprint(b)
    assert shard.last_traffic.total_bytes == 0


def test_host_count_mismatch_rejected():
    ts, data, channels = _make_fleet(48, bad_host=5, seed=23)
    shard = ShardedFleetMonitor(_plan(), use_kernels=False)
    with pytest.raises(ValueError, match="plan covers"):
        shard.diagnose_fleet(ts, data[:40], channels)


# ----------------------------------------------------------- provider API

def test_provider_clean_round_visits_each_shard_once():
    ts, data, channels = _make_fleet(48, bad_host=5, seed=29)
    mono, shard = _pair()
    plan, calls = shard.plan, []

    def provider(s):
        calls.append(s)
        a, b = plan.bounds[s]
        return data[a:b], None

    fp = verdict_fingerprint(shard.diagnose_sharded(ts, provider, channels))
    assert calls == [0, 1, 2]
    assert fp == verdict_fingerprint(mono.diagnose_fleet(ts, data, channels))


def test_provider_revisits_fast_path_shards_on_late_corruption():
    """Corruption first surfaces on the LAST shard: the earlier shards
    already took the fast path, so the round must re-visit exactly them
    through the masked oracle — and still match the single-slab masked
    round, which takes the oracle for every host."""
    ts, data, channels = _make_fleet(48, bad_host=5, seed=31)
    li = channels.index(LAT)
    valid = np.ones(data.shape, bool)
    valid[44, li, -200:] = False           # shard 2 only, below quarantine
    mono, shard = _pair()
    plan, calls = shard.plan, []

    def provider(s):
        calls.append(s)
        a, b = plan.bounds[s]
        return data[a:b], valid[a:b]

    fd = shard.diagnose_sharded(ts, provider, channels)
    assert calls == [0, 1, 2, 0, 1]        # shard 2 already ran the oracle
    ref = mono.diagnose_fleet(ts, data, channels, valid=valid)
    assert verdict_fingerprint(fd) == verdict_fingerprint(ref)
    assert _state_no_plan(shard) == mono.state_dict()


def test_provider_short_window_refuses_before_any_state_advances():
    ts, data, channels = _make_fleet(48, bad_host=5, seed=37)
    shard = ShardedFleetMonitor(_plan(), use_kernels=False)
    calls = []

    def provider(s):
        calls.append(s)
        a, b = shard.plan.bounds[s]
        return data[a:b, :, :40], None

    fd = shard.diagnose_sharded(ts[:40], provider, channels)
    assert calls == [0]                    # refused on the first shard
    assert fd.flagged_hosts == []
    assert "short_baseline_skip" in fd.stage_seconds


def test_provider_shape_mismatch_rejected():
    ts, data, channels = _make_fleet(48, bad_host=5, seed=41)
    shard = ShardedFleetMonitor(_plan(), use_kernels=False)
    with pytest.raises(ValueError, match="bounds"):
        shard.diagnose_sharded(ts, lambda s: (data[:4], None), channels)


# ------------------------------------------------------------- aggregator

def _agents(n_hosts, bad_host, seed=840):
    from repro.sim.scenario import make_trial
    from repro.telemetry.agent import TelemetryAgent
    from repro.telemetry.collectors import SimCollector
    agents = []
    for h in range(n_hosts):
        t = make_trial(seed + h, "nic",
                       intensity=(2.0 if h == bad_host else 0.0),
                       t_on=40.0, confuser_prob=0.0)
        agents.append(TelemetryAgent(
            [SimCollector(t.channels, t.ts, t.data)],
            rate_hz=100.0, history_s=60.0))
    return agents


def test_aggregator_late_joiner_on_nonzero_shard_parity():
    """A host that restarted 3 s ago sits on the LAST shard: the
    aggregator masks it quiet, the sharded round neither flags it nor
    lets its backfilled head poison its shard, and the verdict matches
    the single-slab monitor on the same staged slab."""
    agents = _agents(6, bad_host=1)
    agg = FleetAggregator(agents, window_s=30.0)
    for a in agents[:5]:
        a.run_virtual(0.0, 46.0)
    agents[5].run_virtual(43.0, 46.0)      # young host on shard 2
    plan = agg.shard_plan(shard_hosts=2, rack_shards=2)
    assert plan.shard_of(5) == 2
    shard = ShardedFleetMonitor(plan, use_kernels=False)
    fd = agg.diagnose(shard, min_valid_s=10.0)
    assert fd is not None
    assert fd.straggler_host == 1
    assert 5 not in fd.flagged_hosts
    assert agg.last_snapshot.masked == [5]
    ref = agg.diagnose(FleetMonitor(use_kernels=False), min_valid_s=10.0)
    assert verdict_fingerprint(fd) == verdict_fingerprint(ref)


# ------------------------------------------------------------- checkpoint

def test_state_dict_round_trip_partitioned_state():
    """Strike + quarantine maps built across shard boundaries survive a
    state_dict round trip into a fresh sharded monitor: the next round is
    fingerprint-identical to the monitor that lived through."""
    ts, data, channels = _make_fleet(48, bad_host=41, seed=43)
    li = channels.index(LAT)
    valid = np.ones(data.shape, bool)
    valid[3, li, -1200:] = False           # quarantine path on shard 0
    mono, shard = _pair(persistent_threshold=2)
    for _ in range(2):
        mono.diagnose_fleet(ts, data, channels, valid=valid)
        shard.diagnose_fleet(ts, data, channels, valid=valid)
    fresh = ShardedFleetMonitor(_plan(), use_kernels=False,
                                persistent_threshold=2)
    fresh.load_state_dict(shard.state_dict())
    a = shard.diagnose_fleet(ts, data, channels, valid=valid)
    b = fresh.diagnose_fleet(ts, data, channels, valid=valid)
    assert a.quarantined == [3]
    assert verdict_fingerprint(a) == verdict_fingerprint(b)
    # and the single-slab monitor adopts the same payload (absolute host
    # ids make the state shard-agnostic; the plan key is ignored)
    single = FleetMonitor(use_kernels=False, persistent_threshold=2)
    single.load_state_dict(shard.state_dict())
    c = single.diagnose_fleet(ts, data, channels, valid=valid)
    assert verdict_fingerprint(a) == verdict_fingerprint(c)


def test_plan_mismatch_rejected():
    shard = ShardedFleetMonitor(_plan(), use_kernels=False)
    other = ShardedFleetMonitor(
        ShardPlan.from_bounds([(0, 24), (24, 48)], rack_shards=2),
        use_kernels=False)
    with pytest.raises(ValueError, match="shard plan"):
        other.load_state_dict(shard.state_dict())


def test_session_restore_plan_mismatch_is_counted_cold_start(tmp_path):
    """Resharding between runs must not misattribute strike/quarantine
    state across new boundaries: the session rejects the checkpoint
    loudly and cold-starts."""
    ts, data, channels = _make_fleet(48, bad_host=5, seed=47)
    path = str(tmp_path / "mon.ckpt")
    sess = MonitorSession(ShardedFleetMonitor(_plan(), use_kernels=False),
                          channels)
    sess.tick(ts, data)
    sess.save(path)
    # same plan -> warm restore
    warm = MonitorSession(ShardedFleetMonitor(_plan(), use_kernels=False),
                          channels)
    assert warm.restore(path) is True
    # different plan -> counted cold start, state untouched
    cold = MonitorSession(
        ShardedFleetMonitor(ShardPlan.from_bounds([(0, 48)]),
                            use_kernels=False), channels)
    with pytest.warns(RuntimeWarning, match="cold start"):
        assert cold.restore(path) is False
    assert cold.stats.checkpoints_rejected == 1
    assert cold.monitor._strikes == {}


# ----------------------------------------- incremental streaming moments

def test_incremental_sharded_parity_growing_rounds():
    """Per-shard incremental state keyed by absolute host id (base=
    offsets): fingerprints match the single-slab incremental monitor
    round for round across appended-delta rounds, a masked chaos round
    (forced invalidation + oracle), and the rebuild round after it."""
    ts, data, channels = _make_fleet(48, bad_host=5, seed=3)
    li = list(channels).index(LAT)
    T = data.shape[2]
    mono, shard = _pair()
    assert shard._inc is not None     # incremental on by default
    for rnd, tk in enumerate((T - 240, T - 160, T - 80, T)):
        vmask = None
        if rnd == 2:
            vmask = np.ones((48, len(channels), tk), bool)
            vmask[27, li, -100:] = False     # corruption in shard 1
        a = mono.diagnose_fleet(ts[:tk], data[:, :, :tk], channels,
                                valid=vmask)
        b = shard.diagnose_fleet(ts[:tk], data[:, :, :tk], channels,
                                 valid=vmask)
        assert verdict_fingerprint(a) == verdict_fingerprint(b), rnd
    assert _state_no_plan(shard) == mono.state_dict()
    st = shard.incremental_stats()
    assert st["forced_invalidations"] >= 48      # chaos dropped all rows
    assert st["parity"] == 1.0


def test_incremental_sharded_provider_revisit_invalidates():
    """Provider path with late-surfacing corruption: fast-path shards
    are re-visited through the oracle, which must invalidate (not
    advance) their incremental rows — the next clean round rebuilds."""
    ts, data, channels = _make_fleet(48, bad_host=5, seed=3)
    li = list(channels).index(LAT)
    _, shard = _pair()

    def provider_clean(s):
        a, b = shard.plan.bounds[s]
        return data[a:b], None

    def provider_corrupt(s):
        a, b = shard.plan.bounds[s]
        v = np.ones_like(data[a:b], bool)
        if s == 2:                    # last shard reports corruption
            v[1, li, -100:] = False
        return data[a:b], v

    shard.diagnose_sharded(ts, provider_clean, channels)
    assert shard._inc.rounds == shard.plan.n_shards
    shard.diagnose_sharded(ts, provider_corrupt, channels)
    # shards that ran the fast path before the corruption surfaced may
    # have advanced, but the oracle re-visit must wipe every row — no
    # stale state can survive a round whose verdicts came from the oracle
    assert (shard._inc._bid[:48] == -1).all()
    assert shard._inc.forced_invalidations >= 48
    after = shard._inc.rounds
    shard.diagnose_sharded(ts, provider_clean, channels)
    assert shard._inc.rounds == after + shard.plan.n_shards
    assert shard._inc.parity == 1.0
