"""Serving correctness: prefill+decode == pure step-by-step decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import build_model
from repro.serve.engine import ServeEngine


@pytest.mark.parametrize("arch", ["yi-9b", "mixtral-8x7b", "paligemma-3b",
                                  "mamba2-370m", "jamba-v0.1-52b"])
def test_prefill_matches_stepping(arch):
    """The fused prefill's last-token logits must match feeding the prompt
    token-by-token through decode (the strongest cache-correctness check)."""
    cfg = get_config(arch, smoke=True)
    if cfg.n_experts:
        # capacity dropping differs between prefill (per-sequence capacity)
        # and stepping (per-token) — that is GShard-correct behaviour, not a
        # cache bug; disable drops so the comparison isolates the cache.
        cfg = cfg.replace(capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 12
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    batch = {"tokens": prompts}
    extra = {}
    if cfg.family == "vlm":
        img = jnp.asarray(rng.standard_normal(
            (B, cfg.n_img_tokens, cfg.d_model)) * 0.02, jnp.bfloat16)
        batch["img"] = img
    logits_p, cache_p = jax.jit(
        lambda p, b: model.prefill(p, b, 64))(params, batch)

    # step-by-step path
    cache = model.init_cache(B, 64)
    dec = jax.jit(model.decode)
    if cfg.family == "vlm":
        # feed image tokens via prefill only; stepping path not defined for
        # embeddings -> compare on pure-text archs only
        return
    logits_s = None
    for i in range(S):
        logits_s, cache = dec(params, prompts[:, i:i + 1], cache)
    a = np.asarray(logits_p[:, -1, :cfg.vocab], np.float32)
    b = np.asarray(logits_s[:, -1, :cfg.vocab], np.float32)
    # SSD chunked-scan (prefill) vs sequential recurrence (decode) differ by
    # bf16 accumulation order -> wider tolerance for SSM-bearing archs
    atol = 0.3 if cfg.ssm_state else 0.15
    np.testing.assert_allclose(a, b, rtol=0.15, atol=atol)
    # same argmax (the actual serving contract) — tie-aware: bf16
    # accumulation-order differences can flip a numerically tied top-2,
    # so where the argmaxes disagree BOTH paths must score the two
    # contenders within tolerance of each other; a genuine ranking
    # change still fails
    ia, ib = a.argmax(-1), b.argmax(-1)
    for r in np.flatnonzero(ia != ib):
        assert abs(a[r, ia[r]] - a[r, ib[r]]) <= atol, (r, ia[r], ib[r])
        assert abs(b[r, ia[r]] - b[r, ib[r]]) <= atol, (r, ia[r], ib[r])


def test_generate_greedy_deterministic():
    cfg = get_config("mamba2-370m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    eng = ServeEngine(model, params, max_len=64)
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab, (2, 6)).astype(np.int32)
    r1 = eng.generate(prompts, n_new=8)
    eng2 = ServeEngine(model, params, max_len=64)
    r2 = eng2.generate(prompts, n_new=8)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r1.tokens.shape == (2, 8)


def test_sliding_window_ring_cache():
    """Decoding far past the window keeps the cache bounded and finite."""
    cfg = get_config("mixtral-8x7b", smoke=True)   # window=64 in smoke
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    B = 1
    cache = model.init_cache(B, 256)
    assert cache["k"].shape[2] == cfg.window      # ring-bounded
    dec = jax.jit(model.decode)
    tok = jnp.ones((B, 1), jnp.int32)
    for _ in range(cfg.window + 10):
        logits, cache = dec(params, tok, cache)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["pos"]) == cfg.window + 10
