import numpy as np
import pytest

from repro.core.spike import (
    baseline_stats, detect, spike_score, spike_scores_matrix,
)


def test_baseline_stats_floor():
    mu, sd = baseline_stats(np.full(100, 5.0))
    assert mu == pytest.approx(5.0)
    assert sd >= 1e-3 * 5.0  # sigma floor kicks in on a flat series


def test_spike_score_basic():
    rng = np.random.default_rng(0)
    base = rng.normal(10, 1, 2000)
    mu, sd = baseline_stats(base)
    win = rng.normal(10, 1, 500)
    win[100:] += 8.0
    s = spike_score(win, mu, sd)
    assert s > 3.0


def test_detect_persistence_gates_single_sample():
    rng = np.random.default_rng(1)
    base = rng.normal(10, 1, 2000)
    win = rng.normal(10, 1, 500)
    win[250] = 30.0  # single outlier
    hit, score, onset = detect(win, base, threshold=3.0, persistence=0.3)
    assert not hit and score > 3.0
    hit2, _, onset2 = detect(win, base, threshold=3.0, persistence=0.0)
    # persistence=0 reproduces the bare rule: fires, onset at the first
    # above-threshold sample (ambient tails may cross before the outlier)
    assert hit2 and onset2 <= 250


def test_detect_onset_index():
    rng = np.random.default_rng(2)
    base = rng.normal(5, 0.5, 2000)
    win = rng.normal(5, 0.5, 500)
    win[200:] += 6.0
    hit, _, onset = detect(win, base, persistence=0.3)
    assert hit
    assert 195 <= onset <= 210


def test_scores_matrix_matches_scalar():
    rng = np.random.default_rng(3)
    W = rng.normal(0, 1, (5, 300))
    B = rng.normal(0, 1, (5, 1000))
    W[2, 50:] += 10
    s = spike_scores_matrix(W, B)
    assert s.shape == (5,)
    assert np.argmax(s) == 2
    for i in range(5):
        mu, sd = baseline_stats(B[i])
        assert s[i] == pytest.approx(spike_score(W[i], mu, sd), rel=1e-9)
