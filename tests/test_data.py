import numpy as np

from repro.data.pipeline import PipelineConfig, SyntheticLMPipeline


def test_batch_determinism():
    p1 = SyntheticLMPipeline(PipelineConfig(batch=4, seq_len=16, vocab=100,
                                            seed=3))
    p2 = SyntheticLMPipeline(PipelineConfig(batch=4, seq_len=16, vocab=100,
                                            seed=3))
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_labels_are_shifted_tokens():
    p = SyntheticLMPipeline(PipelineConfig(batch=2, seq_len=16, vocab=50,
                                           seed=0, motif_prob=1.0))
    b = p.batch_at(0)
    # for motif rows, labels[t] should equal tokens[t+1] of the same stream
    np.testing.assert_array_equal(b["tokens"][0, 1:], b["labels"][0, :-1])


def test_prefetch_stream():
    p = SyntheticLMPipeline(PipelineConfig(batch=2, seq_len=8, vocab=30,
                                           seed=0, prefetch=2))
    p.start(start_step=5)
    it = iter(p)
    batches = [next(it) for _ in range(3)]
    p.stop()
    # first prefetched batch is batch_at(5)
    np.testing.assert_array_equal(batches[0]["tokens"],
                                  p.batch_at(5)["tokens"])


def test_vlm_and_encdec_extras():
    p = SyntheticLMPipeline(PipelineConfig(batch=2, seq_len=8, vocab=30,
                                           seed=0, frames_dim=16,
                                           img_tokens=4, img_dim=16))
    b = p.batch_at(0)
    assert b["frames"].shape == (2, 8, 16)
    assert b["img"].shape == (2, 4, 16)
