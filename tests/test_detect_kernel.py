"""Streaming fleet-detect kernel: exact parity vs the scalar-rule oracle
(`detect_rows` + `spike_scores_matrix`) and the fleet-detect edge cases —
onset at the window edge, every host flagged, single-host fleets."""
import numpy as np
import pytest

from repro.core.spike import detect_rows, spike_scores_matrix
from repro.kernels.detect import detect_hosts, persistence_count
from repro.monitor.fleet import FleetMonitor
from repro.sim.scenario import make_trial


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("H,Nw,Nb", [(1, 500, 2000), (7, 128, 128),
                                     (37, 500, 1900), (64, 512, 2048)])
def test_exact_parity_vs_detect_rows(use_kernel, H, Nw, Nb):
    rng = np.random.default_rng(H * 1000 + Nw)
    w = (rng.standard_normal((H, Nw)) * 2 + 5).astype(np.float32)
    b = (rng.standard_normal((H, Nb)) * 2 + 5).astype(np.float32)
    # a mix of firing, marginal and quiet rows
    w[0, Nw // 4: 3 * Nw // 4] += 25.0
    if H > 2:
        w[2, -5:] += 40.0          # hot tail, fails persistence
    fire, score, onset = detect_hosts(w, b, 3.0, 0.35, use_kernel=use_kernel)
    f0, s0, o0 = detect_rows(w, b, 3.0, 0.35)
    np.testing.assert_array_equal(fire, f0)
    np.testing.assert_array_equal(onset, o0)
    np.testing.assert_allclose(score, s0, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(score, spike_scores_matrix(w, b),
                               rtol=1e-4, atol=1e-4)


def test_onset_exactly_at_window_edges():
    rng = np.random.default_rng(0)
    w = (rng.standard_normal((3, 500)) * 0.1 + 5).astype(np.float32)
    b = (rng.standard_normal((3, 2000)) * 0.1 + 5).astype(np.float32)
    w[0, 0:] += 30.0               # onset at the very first sample
    w[1, -1] += 30.0               # single hot sample at the last slot
    fire, _, onset = detect_hosts(w, b, 3.0, 0.0)
    f0, _, o0 = detect_rows(w, b, 3.0, 0.0)
    np.testing.assert_array_equal(fire, f0)
    np.testing.assert_array_equal(onset, o0)
    assert onset[0] == 0 and bool(fire[0])
    assert onset[1] == 499 and bool(fire[1])


def test_quiet_rows_onset_is_argmax_fallback():
    rng = np.random.default_rng(1)
    w = (rng.standard_normal((5, 500)) * 0.5 + 5).astype(np.float32)
    b = (rng.standard_normal((5, 2000)) * 0.5 + 5).astype(np.float32)
    fire, _, onset = detect_hosts(w, b, 3.0, 0.35)
    _, _, o0 = detect_rows(w, b, 3.0, 0.35)
    np.testing.assert_array_equal(onset, o0)


def test_persistence_count_matches_f64_mean_rule():
    for n in (1, 3, 500, 501, 997):
        for p in (0.0, 0.05, 0.35, 1 / 3, 0.5, 0.9999, 1.0):
            c = persistence_count(n, p)
            for cnt in (max(0, c - 1), c, min(n, c + 1)):
                assert (cnt / n >= p) == (cnt >= c), (n, p, c, cnt)


def _fleet_data(n_hosts, bad_host, cls, seed=0, clip_s=46.0):
    trials = [make_trial(seed + h, cls,
                         intensity=(2.0 if h == bad_host else 0.0),
                         t_on=40.0, confuser_prob=0.0)
              for h in range(n_hosts)]
    t_hi = int(clip_s * trials[0].rate_hz)
    data = np.stack([t.data[:, :t_hi] for t in trials])
    return trials[0].ts[:t_hi], data, trials[0].channels


def test_fleet_fast_detect_matches_oracle_path():
    """Byte-exact flagged/onset parity of the columnar monitor (streaming
    detect + f32 gather) vs the seed path (spike dispatch + f64 replay)."""
    ts, data, channels = _fleet_data(6, 2, "nic", seed=40)
    fast = FleetMonitor(use_kernels=False).diagnose_fleet(ts, data, channels)
    oracle = FleetMonitor(use_kernels=False, fast_detect=False
                          ).diagnose_fleet(ts, data, channels)
    assert fast.flagged_hosts == oracle.flagged_hosts
    assert fast.straggler_host == oracle.straggler_host
    for h in fast.flagged_hosts:
        assert fast.diagnoses[h].event.t_onset \
            == oracle.diagnoses[h].event.t_onset
        assert fast.diagnoses[h].top_cause == oracle.diagnoses[h].top_cause
    np.testing.assert_allclose(fast.per_host_scores, oracle.per_host_scores,
                               rtol=1e-4, atol=1e-4)


def test_single_host_fleet():
    ts, data, channels = _fleet_data(1, 0, "io", seed=60)
    fd = FleetMonitor(use_kernels=False).diagnose_fleet(ts, data, channels)
    assert fd.flagged_hosts == [0]
    assert fd.straggler_host == 0
    assert fd.diagnosis is not None


def test_every_host_flagged():
    ts, data, channels = _fleet_data(4, 0, "cpu", seed=80)
    # make every host the injected one
    data = np.stack([data[0]] * 4)
    fd = FleetMonitor(use_kernels=False).diagnose_fleet(ts, data, channels)
    assert sorted(fd.flagged_hosts) == [0, 1, 2, 3]
    assert set(fd.diagnoses) == {0, 1, 2, 3}


def test_stage_seconds_disjoint_and_complete():
    import time
    ts, data, channels = _fleet_data(3, 1, "nic", seed=90)
    mon = FleetMonitor(use_kernels=False)
    mon.diagnose_fleet(ts, data, channels)      # jit warm-up
    mon._strikes = {}
    t0 = time.perf_counter()
    fd = mon.diagnose_fleet(ts, data, channels)
    wall = time.perf_counter() - t0
    assert set(fd.stage_seconds) == {"detect", "gather", "kernel",
                                     "rank", "assemble"}
    total = sum(fd.stage_seconds.values())
    # disjoint stages sum to (slightly under) the observed wall time
    assert total <= wall + 1e-6


def test_strikes_cleared_per_host_on_recovery():
    """A recovered host loses its strikes even while another stays flagged
    — and the strike dict does not accumulate stale hosts on churn."""
    ts, data, channels = _fleet_data(4, 1, "cpu", seed=120)
    ts2, data2, _ = _fleet_data(4, 2, "cpu", seed=120)
    mon = FleetMonitor(use_kernels=False, persistent_threshold=3)
    mon.diagnose_fleet(ts, data, channels)
    assert mon._strikes.get(1) == 1
    # host 1 recovers, host 2 degrades: 1's strike history must vanish
    mon.diagnose_fleet(ts2, data2, channels)
    assert 1 not in mon._strikes
    assert mon._strikes.get(2) == 1
    # churn back and forth: dict never grows beyond the flagged set
    mon.diagnose_fleet(ts, data, channels)
    assert set(mon._strikes) == {1}
