import sys
import threading
import time

import numpy as np
import pytest

from repro.telemetry.agent import TelemetryAgent
from repro.telemetry.collectors import (
    Collector, DeviceMetricSource, ProcCollector, SimCollector,
    available_proc_sources,
)
from repro.telemetry.ringbuffer import MultiChannelRing, RingBuffer
from repro.telemetry.schema import MetricSpec, SignalGroup
from repro.telemetry.sync import (
    align_windows, counters_to_rates, resample_to_grid,
)


def test_ring_wraparound():
    rb = RingBuffer(8)
    for i in range(20):
        rb.append(float(i), float(i * 10))
    ts, vals = rb.view()
    assert len(rb) == 8
    np.testing.assert_array_equal(ts, np.arange(12, 20))
    np.testing.assert_array_equal(vals, np.arange(120, 200, 10))
    assert rb.latest() == (19.0, 190.0)


def test_multichannel_forward_fill():
    r = MultiChannelRing(["a", "b"], capacity=10)
    r.push_row(0.0, {"a": 1.0, "b": 2.0})
    r.push_row(0.1, {"a": 3.0})          # b missing -> carries forward
    ts, data = r.window(2)
    assert data[r.index["b"], 1] == 2.0
    assert data[r.index["a"], 1] == 3.0


def test_counters_to_rates_handles_reset():
    ts = np.arange(5, dtype=float)
    counts = np.array([100., 200., 300., 50., 150.])  # reset at idx 3
    rates = counters_to_rates(ts, counts)
    assert rates[1] == pytest.approx(100.0)
    assert rates[3] == 0.0               # reset clamps to 0
    assert rates[4] == pytest.approx(100.0)


def test_resample_zoh():
    ts = np.array([0.0, 1.0, 2.0])
    v = np.array([1.0, 2.0, 3.0])
    grid = np.array([0.0, 0.5, 1.0, 1.5, 2.5])
    out = resample_to_grid(ts, v, grid)
    np.testing.assert_array_equal(out, [1, 1, 2, 2, 3])


def test_align_windows():
    s = {
        "fast": (np.arange(0, 10, 0.01), np.ones(1000)),
        "slow": (np.arange(0, 10, 0.1), np.arange(100, dtype=float)),
    }
    grid, out = align_windows(s, rate_hz=100.0, duration_s=5.0)
    assert grid.shape == out["fast"].shape == out["slow"].shape
    assert grid[-1] - grid[0] <= 5.0 + 1e-6


def test_agent_virtual_run_and_overhead():
    ts_arr = np.arange(0, 10, 0.01)
    data = np.vstack([np.full(1000, 5.0), np.sin(ts_arr)])
    sim = SimCollector(["dev_power", "dev_temp"], ts_arr, data)
    agent = TelemetryAgent([sim], rate_hz=100.0, history_s=20.0)
    agent.run_virtual(0.0, 10.0)
    assert agent.stats.samples == 1000
    got_ts, got = agent.window(5.0)
    assert got.shape[1] == 500
    assert agent.stats.busy_seconds > 0


def test_push_block_exact_parity_with_push_row():
    rng = np.random.default_rng(7)
    chans = ["a", "b", "c"]
    ts = np.arange(230) * 0.01
    blk = rng.standard_normal((3, 230)).astype(np.float32)
    r_blk, r_row = MultiChannelRing(chans, 100), MultiChannelRing(chans, 100)
    for k in range(0, 230, 41):       # uneven chunks, wraps several times
        sl = slice(k, min(k + 41, 230))
        r_blk.push_block(ts[sl], blk[:, sl])
    for i in range(230):
        r_row.push_row(ts[i], {c: float(blk[j, i])
                               for j, c in enumerate(chans)})
    t1, d1 = r_blk.window(100)
    t0, d0 = r_row.window(100)
    np.testing.assert_array_equal(t1, t0)
    np.testing.assert_array_equal(d1, d0)


def test_window_zero_copy_view():
    r = MultiChannelRing(["a"], 16)
    r.push_block(np.arange(8) * 0.1, np.arange(8, dtype=np.float32)[None])
    ts_v, d_v = r.window(8, copy=False)
    assert d_v.dtype == np.float32 and not d_v.flags.owndata
    np.testing.assert_array_equal(d_v, r.window(8)[1])
    # wrapped span falls back to a copy, chronological order preserved
    r.push_block(np.arange(8, 20) * 0.1,
                 np.arange(8, 20, dtype=np.float32)[None])
    ts_w, d_w = r.window(16, copy=False)
    np.testing.assert_array_equal(d_w[0], np.arange(4, 20, dtype=np.float32))


def test_columnar_run_virtual_exact_parity():
    """SimCollector-driven trials default to the columnar block path and
    produce bit-identical ring contents vs the per-tick oracle."""
    ts_arr = np.arange(0, 10, 0.01)
    data = np.vstack([np.sin(ts_arr) + 5.0, np.cos(ts_arr)])

    def agent(columnar):
        sim = SimCollector(["dev_power", "dev_temp"], ts_arr, data)
        a = TelemetryAgent([sim], rate_hz=100.0, history_s=20.0)
        a.run_virtual(0.0, 10.0, columnar=columnar)
        return a

    a_col, a_tick = agent(True), agent(False)
    assert a_col.stats.samples == a_tick.stats.samples == 1000
    t1, d1 = a_col.window(10.0)
    t0, d0 = a_tick.window(10.0)
    np.testing.assert_array_equal(t1, t0)
    np.testing.assert_array_equal(d1, d0)
    # the columnar path IS the cheap path (the 250+ Hz headroom claim)
    assert a_col.stats.busy_seconds < a_tick.stats.busy_seconds


def test_columnar_falls_back_with_tick_only_collector():
    ts_arr = np.arange(0, 2, 0.01)
    data = np.vstack([np.full(200, 5.0)])
    sim = SimCollector(["dev_power"], ts_arr, data)
    dev = DeviceMetricSource()
    dev.push(step_latency_ms=1.0)
    a = TelemetryAgent([sim, dev], rate_hz=100.0, history_s=5.0)
    a.run_virtual(0.0, 2.0)           # DeviceMetricSource has no block path
    assert a.stats.samples == 200
    assert a.window(1.0)[1].shape[1] == 100


# ---------------------------------------------------------------------------
# seqlock: torn-read safety under a live writer thread
# ---------------------------------------------------------------------------

def _storm(read_one, writer_target, duration_s=1.0, switch_interval=1e-5):
    """Run ``writer_target`` in a thread while looping ``read_one`` for
    ``duration_s``; tiny GIL switch interval forces real interleaving."""
    stop = threading.Event()
    old = sys.getswitchinterval()
    sys.setswitchinterval(switch_interval)
    t = threading.Thread(target=writer_target, args=(stop,), daemon=True)
    reads = 0
    try:
        t.start()
        t_end = time.perf_counter() + duration_s
        while time.perf_counter() < t_end:
            read_one()
            reads += 1
    finally:
        stop.set()
        t.join(timeout=5.0)
        sys.setswitchinterval(old)
    return reads


def test_ring_read_window_consistent_under_writer_storm():
    """Writer storm: a background thread hot-pushing rows while a reader
    loops ``read_window``.  Every snapshot must be internally consistent —
    each column one instant across all channels, timestamps paired — and
    the retry counter must show the validator actually caught contention."""
    chans = ["a", "b", "c"]
    ring = MultiChannelRing(chans, capacity=256)

    def writer(stop):
        i = 0
        while not stop.is_set():
            v = float(i)
            ring.push_row(v, {"a": v, "b": v, "c": v})
            i += 1

    torn = []

    def read_one():
        ts, d, _ = ring.read_window(64)
        if not ts.size:
            return
        # consistent column: all channels carry the same value, and the
        # value equals the timestamp it was pushed with
        if not (np.all(d == d[0:1, :]) and np.array_equal(d[0], ts)):
            torn.append((ts.copy(), d.copy()))

    reads = _storm(read_one, writer, duration_s=1.0)
    assert reads > 0
    assert not torn, f"{len(torn)}/{reads} torn snapshots slipped through"
    # contention must actually have occurred, or the test proved nothing
    assert ring.torn_retries > 0, \
        "writer storm produced zero retries — increase contention"


def test_agent_window_copy_consistent_under_background_sampling():
    """The satellite bug: even ``copy=True`` snapshots used to read
    head/count/data unsynchronized against the sampling thread.  Drive a
    real background-threaded agent flat out and assert every snapshot
    pairs ts[i] with a fully written column."""
    ts_src = np.arange(4096, dtype=np.float64)
    # every channel carries the tick index -> consistency is checkable
    data_src = np.vstack([ts_src, ts_src]).astype(np.float32)
    sim = SimCollector(["dev_power", "dev_temp"], ts_src, data_src)
    agent = TelemetryAgent([sim], rate_hz=4000.0, history_s=0.25)

    def writer(stop):
        i = 0
        while not stop.is_set():
            agent.step(float(i % 4096))
            i += 1

    torn = []

    def read_one():
        ts, d = agent.window(0.1)           # copy=True: validated snapshot
        if not d.shape[1]:
            return
        # a column is consistent when every channel carries the same tick
        # index OR every channel is NaN — the agent's sampling watchdog
        # explicitly invalidates whole ticks at this (deliberately
        # impossible) 4 kHz deadline, and those marks are not tears.  A
        # half-NaN column would still be torn.
        eq = d == d[0:1, :]
        nan = np.isnan(d)
        if not np.all(np.all(eq, axis=0) | np.all(nan, axis=0)):
            torn.append(d.copy())

    reads = _storm(read_one, writer, duration_s=0.8)
    assert reads > 0 and not torn, f"{len(torn)}/{reads} torn agent windows"


def test_overhead_frac_reads_live_and_survives_restart_cycles():
    """Fig-2a live monitoring: overhead_frac must be nonzero MID-run (the
    seed only accumulated wall time in stop()), and start/stop cycles must
    not double-count the wall."""
    sim = SimCollector(["dev_power"], np.arange(100.0),
                       np.ones((1, 100), np.float32))
    agent = TelemetryAgent([sim], rate_hz=200.0, history_s=2.0)
    agent.run_background()
    time.sleep(0.15)
    live_wall = agent.stats.wall_seconds
    live_frac = agent.stats.overhead_frac
    assert live_wall > 0.1, "wall_seconds not visible mid-run"
    assert live_frac > 0.0, "overhead_frac reads 0.0 while live"
    agent.stop()
    w1 = agent.stats.wall_seconds
    agent.stop()                            # double stop: no double count
    assert agent.stats.wall_seconds == w1
    agent.run_background()                  # restart accumulates a new segment
    time.sleep(0.05)
    agent.stop()
    assert w1 < agent.stats.wall_seconds < w1 + 5.0


class _BlockCounterCollector(Collector):
    """Block-capable collector emitting a cumulative counter — exercises
    the columnar rate conversion and the columnar<->per-tick handoff."""

    metrics = [MetricSpec("nic_rx_bytes", SignalGroup.NET, "B/s", 100.0,
                          monotonic_counter=True)]

    def __init__(self, slope=1000.0):
        self.slope = slope

    def _raw(self, t):
        # non-linear so a wrong dt or stale prev produces a wrong rate
        return self.slope * t + 40.0 * np.sin(t)

    def sample(self, now):
        # f32-rounded like sample_block (and like SimCollector), so the
        # per-tick and columnar paths see bit-identical raw values
        return {"nic_rx_bytes": float(np.float32(self._raw(np.float64(now))))}

    def sample_block(self, grid):
        return {"nic_rx_bytes": self._raw(np.asarray(grid, np.float64)
                                          ).astype(np.float32)}


def test_columnar_counter_rates_interleave_parity_with_per_tick():
    """Satellite bug: a columnar span advanced _prev_ts but left _prev_raw
    stale, so the first step() after the span computed (v - pre_span_raw)
    over a post-span dt.  Interleave columnar spans with per-tick steps on
    a counter channel and require exact ring parity with the all-per-tick
    oracle."""
    def run(columnar):
        a = TelemetryAgent([_BlockCounterCollector()], rate_hz=100.0,
                           history_s=20.0)
        a.run_virtual(0.0, 3.0, columnar=columnar)      # span 1
        for i in range(50):                             # per-tick stretch
            a.step(3.0 + i * 0.01)
        a.run_virtual(3.5, 6.0, columnar=columnar)      # span 2
        a.step(6.0)
        return a

    a_mix, a_tick = run(True), run(False)
    assert a_mix.stats.samples == a_tick.stats.samples
    t1, d1 = a_mix.window(10.0)
    t0, d0 = a_tick.window(10.0)
    np.testing.assert_array_equal(t1, t0)
    np.testing.assert_array_equal(d1, d0)
    # sanity: the rates are real (slope/1s +- sin wiggle), not zeros
    assert np.median(d1[0, 1:]) == pytest.approx(1000.0, rel=0.2)


def test_columnar_counter_first_sample_is_zero_rate():
    """A fresh agent's first columnar sample has no previous raw value:
    rate 0.0, exactly like the per-tick path."""
    a = TelemetryAgent([_BlockCounterCollector()], rate_hz=100.0,
                       history_s=5.0)
    a.run_virtual(0.0, 1.0)
    ts, d = a.window(1.0)
    assert d[0, 0] == 0.0
    assert np.all(d[0, 1:] > 0.0)


def test_proc_collector_runs_on_linux():
    avail = available_proc_sources()
    if not any(avail.values()):
        pytest.skip("no /proc available")
    pc = ProcCollector()
    row1 = pc.sample(0.0)
    assert isinstance(row1, dict) and row1
    # cumulative counters should be monotone across two samples
    row2 = pc.sample(0.1)
    for k in ("net_rx_softirq", "sched_switch_rate"):
        if k in row1 and k in row2:
            assert row2[k] >= row1[k]


def test_device_source_push_drain():
    d = DeviceMetricSource()
    d.push(step_latency_ms=12.5, coll_allreduce_ms=8.0)
    out = d.sample(0.0)
    assert out["step_latency_ms"] == 12.5
    assert out["coll_allreduce_ms"] == 8.0
