#!/usr/bin/env python
"""Docstring-coverage lint (interrogate-style, stdlib-only).

Walks the given files/directories, parses every ``*.py`` with ``ast``, and
counts docstrings on the public API surface: the module itself, public
classes, and public functions/methods (a leading underscore or a dunder
other than ``__init__`` is private; nested ``def``s are implementation
detail and are skipped).  An ``__init__`` counts only when it has a
non-trivial body — a bare dataclass-style pass-through has nothing to say.

Exit status is non-zero when coverage falls below ``--fail-under``, which
is how CI pins the floor so documentation cannot silently regress:

    python tools/docs_lint.py src/repro/monitor --fail-under 100
    python tools/docs_lint.py src benchmarks tools --fail-under 90 -v

``-v`` lists every undocumented definition as ``path:line  kind name``.
"""
from __future__ import annotations

import argparse
import ast
import os
import sys
from typing import Iterator, List, Tuple

#: (path, line, kind, qualname, documented)
Record = Tuple[str, int, str, str, bool]


def _is_public(name: str) -> bool:
    if name.startswith("__") and name.endswith("__"):
        return name == "__init__"
    return not name.startswith("_")


def _trivial_init(node: ast.FunctionDef) -> bool:
    """An ``__init__`` whose body is only pass/docstring/attr-assigns of
    its own arguments — nothing a docstring would add over the signature."""
    if node.name != "__init__":
        return False
    body = node.body
    if body and isinstance(body[0], ast.Expr) and isinstance(
            body[0].value, ast.Constant):
        body = body[1:]
    return all(isinstance(s, (ast.Pass, ast.Assign, ast.AnnAssign))
               for s in body)


def _walk_defs(tree: ast.Module, path: str) -> Iterator[Record]:
    yield (path, 1, "module", os.path.basename(path),
           ast.get_docstring(tree) is not None)
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _is_public(node.name):
                yield (path, node.lineno, "function", node.name,
                       ast.get_docstring(node) is not None)
        elif isinstance(node, ast.ClassDef) and _is_public(node.name):
            yield (path, node.lineno, "class", node.name,
                   ast.get_docstring(node) is not None)
            for sub in node.body:
                if not isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    continue
                if not _is_public(sub.name) or _trivial_init(sub):
                    continue
                yield (path, sub.lineno, "method",
                       f"{node.name}.{sub.name}",
                       ast.get_docstring(sub) is not None)


def collect(paths: List[str]) -> List[Record]:
    """All public-API docstring records under ``paths`` (files or dirs)."""
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for root, dirs, names in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if d not in ("__pycache__", ".git"))
            files.extend(os.path.join(root, n) for n in sorted(names)
                         if n.endswith(".py"))
    records: List[Record] = []
    for f in files:
        with open(f, "r", encoding="utf-8") as fh:
            try:
                tree = ast.parse(fh.read(), filename=f)
            except SyntaxError as e:
                raise SystemExit(f"docs_lint: cannot parse {f}: {e}")
        records.extend(_walk_defs(tree, f))
    return records


def coverage(records: List[Record]) -> float:
    """Documented fraction in percent (100.0 for an empty surface)."""
    if not records:
        return 100.0
    return 100.0 * sum(1 for r in records if r[4]) / len(records)


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail when public-API docstring coverage regresses.")
    ap.add_argument("paths", nargs="+", help="files or directories to scan")
    ap.add_argument("--fail-under", type=float, default=90.0,
                    help="minimum coverage percent (default 90)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="list undocumented definitions")
    args = ap.parse_args(argv)

    records = collect(args.paths)
    missing = [r for r in records if not r[4]]
    if args.verbose:
        for path, line, kind, name, _ in missing:
            print(f"{path}:{line}  {kind} {name}")
    pct = coverage(records)
    ok = pct >= args.fail_under
    status = "ok" if ok else "FAIL"
    print(f"docs_lint: {len(records) - len(missing)}/{len(records)} "
          f"documented = {pct:.1f}% (fail-under {args.fail_under:g}) "
          f"[{status}]")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
